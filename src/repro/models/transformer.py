"""Unified config-driven transformer family.

One implementation covers all ten assigned architectures:

* dense decoder (llama-arch: deepseek-coder, minicpm, qwen2, granite)
* MoE decoder (mixtral 8e, phi3.5-moe 16e) — models/moe.py
* SSM decoder (falcon-mamba) — models/ssm.py Mamba-1 blocks
* hybrid (recurrentgemma: RG-LRU + local attention, 1:2)
* encoder–decoder (seamless-m4t backbone; audio frontend is a stub that
  feeds precomputed frame embeddings)
* VLM (llama-3.2-vision backbone: gated cross-attention image layers every
  Nth layer; patch embeddings stubbed)

Design notes:
* layers execute through `lax.scan` over the repeating *super-block* (the
  unit of the layer pattern), so HLO size is O(1) in depth and remat policy
  applies per super-block;
* everything is pure functions over explicit param pytrees; `init_params`
  runs under `jax.eval_shape` for the allocation-free dry-run;
* decode carries a cache pytree scanned alongside the stacked params.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_lib
from . import moe as moe_lib
from . import ssm as ssm_lib
from .layers import apply_rope, rmsnorm, softmax_cross_entropy

PyTree = Any


def _constrain_act(x: jax.Array, cfg: "ModelConfig") -> jax.Array:
    """Pin (B, S, D) activations to the policy's batch/seq sharding."""
    if cfg.act_sharding is None:
        return x
    from jax.sharding import PartitionSpec as P
    b_ax, s_ax = cfg.act_sharding
    s_ax = s_ax if x.shape[1] > 1 else None     # decode: single position
    return jax.lax.with_sharding_constraint(x, P(b_ax, s_ax, None))


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    n_layers: int = 12
    d_model: int = 512
    n_heads: int = 8
    n_kv_heads: int = 8
    d_ff: int = 2048
    vocab: int = 32000
    head_dim: int = 0                       # 0 -> d_model // n_heads
    qkv_bias: bool = False
    gated_mlp: bool = True                  # SwiGLU (3 mats) vs plain (2)
    attn_window: Optional[int] = None       # SWA width (None = full)
    block_pattern: Tuple[str, ...] = ("attn",)   # unit: attn|rec|mamba|xattn
    cross_attn_every: int = 0               # vision: xattn every Nth layer
    # MoE
    n_experts: int = 0
    moe_top_k: int = 2
    capacity_factor: float = 1.25
    # SSM
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    scan_chunk: int = 256
    # encoder-decoder
    encoder_decoder: bool = False
    enc_layers: int = 0
    # misc
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    attention_impl: str = "chunked"         # dot | chunked | pallas
    attn_chunk: int = 1024
    # paged serving decode: "ref" (gather + dense decode attention; bit-
    # identical to the dense slot cache — the CPU/CI default) or "pallas"
    # (kernels/paged_attention.py, in-kernel page gather on TPU)
    paged_attention_impl: str = "ref"
    remat: bool = True
    frontend: str = "none"                  # none | audio | vision
    img_seq: int = 6404                     # vision stub: 4 tiles x 1601
    # microbatching: split the global batch into this many sequential
    # microbatches per step (gradient accumulation) — the production lever
    # for fitting train-step activation memory in HBM
    grad_accum: int = 1
    # activation sharding constraint (batch_axes, seq_axes) — mesh axis names
    # injected by launch/steps.py; pins (B, S, D) activations so GSPMD does
    # not trade the batch shard for a param-storage shard (ZeRO-3 semantics)
    act_sharding: Optional[Tuple[Any, Any]] = None
    # logits sharding constraint (vocab mesh axes) — perf knob for the loss
    logits_vocab_shard: Optional[Any] = None

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    def pattern_unit(self) -> Tuple[str, ...]:
        if self.cross_attn_every > 0:
            return tuple(["attn"] * (self.cross_attn_every - 1) + ["xattn"])
        return self.block_pattern

    def layer_types(self) -> List[str]:
        unit = self.pattern_unit()
        out = [unit[i % len(unit)] for i in range(self.n_layers)]
        return out

    @property
    def n_super(self) -> int:
        return self.n_layers // len(self.pattern_unit())

    @property
    def n_rem(self) -> int:
        return self.n_layers % len(self.pattern_unit())

    def mamba_args(self) -> ssm_lib.MambaArgs:
        return ssm_lib.MambaArgs(self.d_model, self.ssm_state, self.ssm_conv,
                                 self.ssm_expand, self.scan_chunk)

    def rglru_args(self) -> ssm_lib.RGLRUArgs:
        return ssm_lib.RGLRUArgs(self.d_model, self.ssm_conv, self.ssm_expand,
                                 chunk=self.scan_chunk)

    def moe_args(self) -> moe_lib.MoEArgs:
        return moe_lib.MoEArgs(self.d_model, self.d_ff, self.n_experts,
                               self.moe_top_k, self.capacity_factor)


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------
def _dense(key, shape, dtype, fan_in=None):
    fan_in = fan_in or shape[0]
    return (jax.random.normal(key, shape, jnp.float32)
            * (2.0 / fan_in) ** 0.5).astype(dtype)


def _init_attn(key, cfg: ModelConfig, cross: bool = False) -> Dict:
    ks = jax.random.split(key, 4)
    d, h, hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    p = {
        "wq": _dense(ks[0], (d, h * hd), cfg.param_dtype),
        "wk": _dense(ks[1], (d, hk * hd), cfg.param_dtype),
        "wv": _dense(ks[2], (d, hk * hd), cfg.param_dtype),
        "wo": _dense(ks[3], (h * hd, d), cfg.param_dtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((h * hd,), cfg.param_dtype)
        p["bk"] = jnp.zeros((hk * hd,), cfg.param_dtype)
        p["bv"] = jnp.zeros((hk * hd,), cfg.param_dtype)
    return p


def _init_mlp(key, cfg: ModelConfig) -> Dict:
    if cfg.n_experts > 0:
        return moe_lib.init_moe_params(key, cfg.moe_args(), cfg.param_dtype)
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    p = {
        "w_up": _dense(ks[1], (d, f), cfg.param_dtype),
        "w_down": _dense(ks[2], (f, d), cfg.param_dtype),
    }
    if cfg.gated_mlp:
        p["w_gate"] = _dense(ks[0], (d, f), cfg.param_dtype)
    return p


def _init_block(key, btype: str, cfg: ModelConfig, with_cross: bool) -> Dict:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    zeros = lambda: jnp.zeros((d,), cfg.param_dtype)
    if btype == "attn":
        p = {"ln1": zeros(), "attn": _init_attn(ks[0], cfg),
             "ln2": zeros(), "mlp": _init_mlp(ks[1], cfg)}
        if with_cross:
            p["lnx"] = zeros()
            p["xattn"] = _init_attn(ks[2], cfg, cross=True)
        return p
    if btype == "xattn":  # vision: gated cross-attention layer
        return {"ln1": zeros(), "xattn": _init_attn(ks[0], cfg, cross=True),
                "ln2": zeros(), "mlp": _init_mlp(ks[1], cfg),
                "gate_attn": jnp.zeros((), cfg.param_dtype),
                "gate_mlp": jnp.zeros((), cfg.param_dtype)}
    if btype == "rec":
        return {"ln1": zeros(),
                "rec": ssm_lib.init_rglru_params(ks[0], cfg.rglru_args(),
                                                 cfg.param_dtype),
                "ln2": zeros(), "mlp": _init_mlp(ks[1], cfg)}
    if btype == "mamba":
        return {"ln1": zeros(),
                "mamba": ssm_lib.init_mamba_params(ks[0], cfg.mamba_args(),
                                                   cfg.param_dtype)}
    raise ValueError(btype)


def _init_stack(key, cfg: ModelConfig, with_cross: bool) -> Dict:
    """Scanned super-block stacks + remainder layers."""
    unit = cfg.pattern_unit()
    kb, kr = jax.random.split(key)
    blocks = []
    for j, btype in enumerate(unit):
        keys = jax.random.split(jax.random.fold_in(kb, j), max(cfg.n_super, 1))
        init_one = functools.partial(_init_block, btype=btype, cfg=cfg,
                                     with_cross=with_cross)
        blocks.append(jax.vmap(lambda k: init_one(k))(keys))
    rem = []
    for i in range(cfg.n_rem):
        btype = unit[i % len(unit)]
        rem.append(_init_block(jax.random.fold_in(kr, i), btype, cfg,
                               with_cross))
    return {"blocks": tuple(blocks), "rem": tuple(rem)}


def init_params(key: jax.Array, cfg: ModelConfig) -> Dict:
    ke, kd, kenc, kh = jax.random.split(key, 4)
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(ke, (cfg.vocab, cfg.d_model), jnp.float32)
                  * 0.02).astype(cfg.param_dtype),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.param_dtype),
        "decoder": _init_stack(kd, cfg, with_cross=cfg.encoder_decoder),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense(kh, (cfg.d_model, cfg.vocab),
                                   cfg.param_dtype)
    if cfg.encoder_decoder:
        enc_cfg = dataclasses.replace(
            cfg, n_layers=cfg.enc_layers or cfg.n_layers,
            block_pattern=("attn",), cross_attn_every=0, encoder_decoder=False)
        params["encoder"] = _init_stack(kenc, enc_cfg, with_cross=False)
        params["enc_final_norm"] = jnp.zeros((cfg.d_model,), cfg.param_dtype)
    return params


# ---------------------------------------------------------------------------
# Blocks (full-sequence mode)
# ---------------------------------------------------------------------------
def _project_qkv(p, h_in, cfg: ModelConfig, kv_src=None):
    cd = cfg.compute_dtype
    src = h_in if kv_src is None else kv_src
    q = jnp.dot(h_in, p["wq"].astype(cd))
    k = jnp.dot(src, p["wk"].astype(cd))
    v = jnp.dot(src, p["wv"].astype(cd))
    if "bq" in p:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    b, s = h_in.shape[0], h_in.shape[1]
    t = src.shape[1]
    q = q.reshape(b, s, cfg.n_heads, cfg.hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, t, cfg.n_kv_heads, cfg.hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, t, cfg.n_kv_heads, cfg.hd).transpose(0, 2, 1, 3)
    return q, k, v


def _merge_heads(out, p, cfg: ModelConfig):
    b, h, s, hd = out.shape
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    return jnp.dot(out, p["wo"].astype(cfg.compute_dtype))


def _self_attention(p, x, cfg: ModelConfig, *, causal: bool,
                    positions: jax.Array, emit_cache: bool):
    h_in = rmsnorm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _project_qkv(p["attn"], h_in, cfg)
    q = apply_rope(q, positions[None, None], cfg.rope_theta)
    k = apply_rope(k, positions[None, None], cfg.rope_theta)
    out = attn_lib.attend(q, k, v, impl=cfg.attention_impl, causal=causal,
                          window=cfg.attn_window, kv_chunk=cfg.attn_chunk)
    x = x + _merge_heads(out, p["attn"], cfg)
    cache = None
    if emit_cache:
        w = cfg.attn_window
        if w is not None and k.shape[2] > w:
            # rolling buffer: keep the last `w` positions, laid out so that
            # slot (pos % w) holds position pos — matches decode writes
            t = k.shape[2]
            idx = (jnp.arange(w) + (t // w) * w)
            idx = jnp.where(idx < t, idx, idx - w)
            k, v = k[:, :, idx], v[:, :, idx]
        cache = {"k": k.astype(cfg.compute_dtype),
                 "v": v.astype(cfg.compute_dtype)}
    return x, cache


def _cross_attention(p, x, kv_feats, cfg: ModelConfig, key: str = "xattn"):
    h_in = rmsnorm(x, p["lnx" if key == "xattn" and "lnx" in p else "ln1"],
                   cfg.norm_eps)
    q, k, v = _project_qkv(p[key], h_in, cfg, kv_src=kv_feats)
    out = attn_lib.attend(q, k, v, impl="dot" if x.shape[1] == 1 else
                          cfg.attention_impl, causal=False,
                          kv_chunk=cfg.attn_chunk)
    return _merge_heads(out, p[key], cfg)


def _mlp_core(p, h_in, cfg: ModelConfig):
    cd = cfg.compute_dtype
    if cfg.gated_mlp:
        h = jax.nn.silu(jnp.dot(h_in, p["w_gate"].astype(cd))) * \
            jnp.dot(h_in, p["w_up"].astype(cd))
    else:
        h = jax.nn.gelu(jnp.dot(h_in, p["w_up"].astype(cd)))
    return jnp.dot(h, p["w_down"].astype(cd))


def _mlp(p, x, cfg: ModelConfig):
    h_in = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if cfg.n_experts > 0:
        bax = cfg.act_sharding[0] if cfg.act_sharding else None
        return x + moe_lib.moe_apply(p["mlp"], h_in, cfg.moe_args(),
                                     cfg.compute_dtype, batch_axes=bax)
    return x + _mlp_core(p["mlp"], h_in, cfg)


def _apply_block(btype: str, p, x, cfg: ModelConfig, *, causal: bool,
                 positions: jax.Array, cross_feats=None,
                 emit_cache: bool = False):
    """Full-sequence block application.  Returns (x, cache_or_None)."""
    cache = None
    if btype == "attn":
        x, cache = _self_attention(p, x, cfg, causal=causal,
                                   positions=positions, emit_cache=emit_cache)
        if "xattn" in p and cross_feats is not None:      # enc-dec decoder
            x = x + _cross_attention(p, x, cross_feats, cfg)
        x = _mlp(p, x, cfg)
    elif btype == "xattn":                                 # vision layer
        g_a = jnp.tanh(p["gate_attn"].astype(jnp.float32)).astype(x.dtype)
        x = x + g_a * _cross_attention(p, x, cross_feats, cfg, key="xattn")
        h_in = rmsnorm(x, p["ln2"], cfg.norm_eps)
        g_m = jnp.tanh(p["gate_mlp"].astype(jnp.float32)).astype(x.dtype)
        x = x + g_m * _mlp_core(p["mlp"], h_in, cfg)
    elif btype == "rec":
        h_in = rmsnorm(x, p["ln1"], cfg.norm_eps)
        y = ssm_lib.rglru_apply(p["rec"], h_in, cfg.rglru_args(),
                                cfg.compute_dtype, return_state=emit_cache)
        if emit_cache:
            y, state = y
            cache = {"rec": state}
        x = x + y
        x = _mlp(p, x, cfg)
    elif btype == "mamba":
        h_in = rmsnorm(x, p["ln1"], cfg.norm_eps)
        y = ssm_lib.mamba_apply(p["mamba"], h_in, cfg.mamba_args(),
                                cfg.compute_dtype, return_state=emit_cache)
        if emit_cache:
            y, state = y
            cache = {"mamba": state}
        x = x + y
    else:
        raise ValueError(btype)
    return x, cache


def _run_stack(stack, x, cfg: ModelConfig, *, causal: bool,
               positions: jax.Array, cross_feats=None,
               emit_cache: bool = False):
    """Scan over super-blocks, then the remainder layers."""
    unit = cfg.pattern_unit()

    def super_block(carry, xs):
        h = carry
        caches = []
        for j, btype in enumerate(unit):
            h, c = _apply_block(btype, xs[j], h, cfg, causal=causal,
                                positions=positions, cross_feats=cross_feats,
                                emit_cache=emit_cache)
            h = _constrain_act(h, cfg)
            caches.append(c if c is not None else 0)
        return h, tuple(caches)

    body = super_block
    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    caches = None
    if cfg.n_super > 0:
        x, caches = jax.lax.scan(body, x, stack["blocks"])
    rem_caches = []
    for i, p in enumerate(stack["rem"]):
        btype = unit[i % len(unit)]
        x, c = _apply_block(btype, p, x, cfg, causal=causal,
                            positions=positions, cross_feats=cross_feats,
                            emit_cache=emit_cache)
        rem_caches.append(c if c is not None else 0)
    return x, (caches, tuple(rem_caches))


# ---------------------------------------------------------------------------
# Public entry points: forward / loss / prefill / decode
# ---------------------------------------------------------------------------
def encode(params, cfg: ModelConfig, enc_inputs: jax.Array) -> jax.Array:
    """Encoder over precomputed frontend embeddings (B, T, D)."""
    enc_cfg = dataclasses.replace(
        cfg, n_layers=cfg.enc_layers or cfg.n_layers,
        block_pattern=("attn",), cross_attn_every=0, encoder_decoder=False)
    x = _constrain_act(enc_inputs.astype(cfg.compute_dtype), enc_cfg)
    positions = jnp.arange(x.shape[1])
    x, _ = _run_stack(params["encoder"], x, enc_cfg, causal=False,
                      positions=positions)
    return rmsnorm(x, params["enc_final_norm"], cfg.norm_eps)


def forward(params, cfg: ModelConfig, tokens: jax.Array, *,
            enc_inputs: Optional[jax.Array] = None,
            img_embeds: Optional[jax.Array] = None,
            emit_cache: bool = False):
    """Full-sequence forward.  tokens: (B, S) int32 -> logits (B, S, V).

    Returns (logits, cache) — cache is None unless emit_cache (prefill)."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    x = _constrain_act(x, cfg)
    positions = jnp.arange(tokens.shape[1])
    cross_feats = None
    if cfg.encoder_decoder:
        assert enc_inputs is not None, "enc-dec model needs encoder inputs"
        cross_feats = encode(params, cfg, enc_inputs)
    elif cfg.frontend == "vision":
        assert img_embeds is not None, "vision model needs image embeddings"
        cross_feats = img_embeds.astype(cfg.compute_dtype)

    x, caches = _run_stack(params["decoder"], x, cfg, causal=True,
                           positions=positions, cross_feats=cross_feats,
                           emit_cache=emit_cache)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = jnp.dot(x, head.astype(cfg.compute_dtype))
    if cfg.logits_vocab_shard is not None and cfg.act_sharding is not None:
        from jax.sharding import PartitionSpec as P
        b_ax, s_ax = cfg.act_sharding
        logits = jax.lax.with_sharding_constraint(
            logits, P(b_ax, s_ax, cfg.logits_vocab_shard))
    if not emit_cache:
        return logits, None
    cache = {"layers": caches, "pos": jnp.array(tokens.shape[1], jnp.int32),
             "cross": cross_feats}
    return logits, cache


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jax.Array]) -> jax.Array:
    logits, _ = forward(params, cfg, batch["tokens"],
                        enc_inputs=batch.get("enc_inputs"),
                        img_embeds=batch.get("img_embeds"))
    return softmax_cross_entropy(logits, batch["labels"], batch.get("mask"))


# ------------------------------- decode -----------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Dict:
    """Abstract-init-friendly cache pytree matching `_run_stack` emissions."""
    unit = cfg.pattern_unit()
    t = min(cfg.attn_window or max_seq, max_seq)

    def one(btype):
        if btype in ("attn",):
            return {"k": jnp.zeros((batch, cfg.n_kv_heads, t, cfg.hd),
                                   cfg.compute_dtype),
                    "v": jnp.zeros((batch, cfg.n_kv_heads, t, cfg.hd),
                                   cfg.compute_dtype)}
        if btype == "rec":
            return {"rec": ssm_lib.rglru_init_state(cfg.rglru_args(), batch)}
        if btype == "mamba":
            return {"mamba": ssm_lib.mamba_init_state(cfg.mamba_args(), batch)}
        if btype == "xattn":
            # cross-attn reads cache["cross"]; keep a scannable placeholder
            return jnp.zeros((), jnp.int32)
        raise ValueError(btype)

    def stacked(btype):
        c = one(btype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_super,) + a.shape), c)

    blocks = tuple(stacked(b) for b in unit)
    rem = tuple(one(unit[i % len(unit)]) for i in range(cfg.n_rem))
    cross = None
    if cfg.encoder_decoder:
        enc_t = max_seq
        cross = jnp.zeros((batch, enc_t, cfg.d_model), cfg.compute_dtype)
    elif cfg.frontend == "vision":
        cross = jnp.zeros((batch, cfg.img_seq, cfg.d_model), cfg.compute_dtype)
    return {"layers": (blocks, rem), "pos": jnp.zeros((), jnp.int32),
            "cross": cross}


def _decode_attn_block(p, x, cache, cfg: ModelConfig, pos, cross_feats):
    """`pos` is a scalar (whole batch at one position — static batching) or a
    (B,) vector of per-slot positions (continuous batching)."""
    h_in = rmsnorm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _project_qkv(p["attn"], h_in, cfg)
    pos_a = jnp.asarray(pos)
    per_slot = pos_a.ndim == 1
    posq = pos_a[:, None, None] if per_slot else jnp.full((1, 1, 1), pos_a)
    q = apply_rope(q, posq, cfg.rope_theta)
    k = apply_rope(k, posq, cfg.rope_theta)
    t = cache["k"].shape[2]
    if cfg.attn_window is not None:
        slot = pos_a % t                    # rolling buffer
    else:
        slot = jnp.minimum(pos_a, t - 1)
    # one-hot (select-based) cache write: elementwise over the time dim, so
    # a time-SHARDED cache updates locally — dynamic_update_slice at a traced
    # index would force GSPMD to all-gather the cache (measured: +10 GB temp
    # per decode step on kv-unshardable archs)
    onehot = (jnp.arange(t) == slot[..., None])  # (t,) | (B, t)
    onehot = (onehot[:, None, :, None] if per_slot
              else onehot[None, None, :, None])
    k_cache = jnp.where(onehot, k.astype(cache["k"].dtype), cache["k"])
    v_cache = jnp.where(onehot, v.astype(cache["v"].dtype), cache["v"])
    out = attn_lib.decode_attention(q, k_cache, v_cache, pos=pos,
                                    window=cfg.attn_window)
    x = x + _merge_heads(out, p["attn"], cfg)
    if "xattn" in p and cross_feats is not None:
        x = x + _cross_attention(p, x, cross_feats, cfg)
    x = _mlp(p, x, cfg)
    return x, {"k": k_cache, "v": v_cache}


def _decode_block(btype, p, x, cache, cfg: ModelConfig, pos, cross_feats):
    if btype == "attn":
        return _decode_attn_block(p, x, cache, cfg, pos, cross_feats)
    if btype == "xattn":
        g_a = jnp.tanh(p["gate_attn"].astype(jnp.float32)).astype(x.dtype)
        x = x + g_a * _cross_attention(p, x, cross_feats, cfg, key="xattn")
        h_in = rmsnorm(x, p["ln2"], cfg.norm_eps)
        g_m = jnp.tanh(p["gate_mlp"].astype(jnp.float32)).astype(x.dtype)
        x = x + g_m * _mlp_core(p["mlp"], h_in, cfg)
        return x, cache
    if btype == "rec":
        h_in = rmsnorm(x, p["ln1"], cfg.norm_eps)
        y, rec = ssm_lib.rglru_step(p["rec"], h_in, cache["rec"],
                                    cfg.rglru_args(), cfg.compute_dtype)
        x = x + y
        x = _mlp(p, x, cfg)
        return x, {"rec": rec}
    if btype == "mamba":
        h_in = rmsnorm(x, p["ln1"], cfg.norm_eps)
        y, st = ssm_lib.mamba_step(p["mamba"], h_in, cache["mamba"],
                                   cfg.mamba_args(), cfg.compute_dtype)
        return x + y, {"mamba": st}
    raise ValueError(btype)


def _decode_step_impl(params, cfg: ModelConfig, cache: Dict,
                      tokens: jax.Array, active: Optional[jax.Array], *,
                      block_step=None, arena_passthrough: bool = False,
                      pos_increment: int = 1):
    """Shared decode-step body.  With ``active=None`` this is the static
    path (scalar `pos`, whole batch advances); with an (B,) ``active`` mask
    it is the continuous-batching path (per-slot (B,) `pos`, inactive slots
    keep cache and position bit-for-bit).

    ``block_step(btype, p, h, c) -> (h, new_c)`` overrides the per-layer
    step (the paged layout substitutes its attention block);
    ``arena_passthrough`` exempts attention K/V dicts from the per-slot
    keep-select — paged arenas are page-major, not slot-major, and their
    writes are already active-guarded by trash-page routing.  There is
    exactly one copy of everything else (embed, keep semantics, the
    super-block scan, final norm, logits head), so a fix here cannot split
    the layouts' bit-identity."""
    unit = cfg.pattern_unit()
    pos = cache["pos"]
    cross_feats = cache.get("cross")
    b = tokens.shape[0]
    if block_step is None:
        def block_step(btype, p, h, c):
            return _decode_block(btype, p, h, c, cfg, pos, cross_feats)

    if active is None:
        keep = lambda new, old: new
    else:
        def keep(new, old):
            if arena_passthrough and isinstance(new, dict) and "k" in new:
                return new
            def sel(n, o):
                if getattr(n, "ndim", 0) == 0 or n.shape[0] != b:
                    return n                # scannable placeholders (xattn)
                return jnp.where(
                    active.reshape((b,) + (1,) * (n.ndim - 1)), n, o)
            return jax.tree.map(sel, new, old)

    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    x = _constrain_act(x, cfg)

    blocks_cache, rem_cache = cache["layers"]

    def super_block(h, xs):
        ps, cs = xs
        new_cs = []
        for j, btype in enumerate(unit):
            h, nc = block_step(btype, ps[j], h, cs[j])
            h = _constrain_act(h, cfg)
            new_cs.append(keep(nc, cs[j]))
        return h, tuple(new_cs)

    new_blocks = blocks_cache
    if cfg.n_super > 0:
        x, new_blocks = jax.lax.scan(
            super_block, x, (params["decoder"]["blocks"], blocks_cache))
    new_rem = []
    for i, p in enumerate(params["decoder"]["rem"]):
        btype = unit[i % len(unit)]
        x, nc = block_step(btype, p, x, rem_cache[i])
        new_rem.append(keep(nc, rem_cache[i]))

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = jnp.dot(x, head.astype(cfg.compute_dtype))
    # copy-and-update: layout-specific keys (e.g. paged block_tables)
    # survive; the dense cache carries exactly layers/pos/cross either way
    new_cache = dict(cache)
    new_cache["layers"] = (new_blocks, tuple(new_rem))
    new_cache["pos"] = (pos + pos_increment if active is None
                        else jnp.where(active, pos + pos_increment, pos))
    return logits, new_cache


def decode_step(params, cfg: ModelConfig, cache: Dict, tokens: jax.Array):
    """One decode step.  tokens: (B, 1) -> (logits (B, 1, V), new cache)."""
    return _decode_step_impl(params, cfg, cache, tokens, active=None)


# --------------------- slot-indexed decode (serving) -----------------------
def init_slot_cache(cfg: ModelConfig, n_slots: int, max_seq: int) -> Dict:
    """Cache for the continuous-batching engine: each batch row is a *slot*
    owned by (at most) one in-flight request, with its own position counter.
    Identical layout to `init_cache` except ``pos`` is per-slot (B,)."""
    cache = init_cache(cfg, n_slots, max_seq)
    cache["pos"] = jnp.zeros((n_slots,), jnp.int32)
    return cache


def reset_slot_state(cfg: ModelConfig, cache: Dict, slot: int) -> Dict:
    """Clear one slot's per-request state before binding a new request.

    Attention KV entries need no clearing in either layout (per-slot
    position masks hide stale entries — paged arenas additionally never
    alias live blocks), but recurrent SSM states (rec/mamba) carry no
    position and WOULD leak across tenants — those are zeroed, matching
    `init_cache`.  The cache is rebuilt by copy-and-update so every key
    the layout carries (e.g. the paged layout's ``block_tables``)
    survives."""
    def zero_slot(c, axis):
        if not (isinstance(c, dict) and ("rec" in c or "mamba" in c)):
            return c
        def z(leaf):
            idx = (slice(None),) * axis + (slot,)
            return leaf.at[idx].set(jnp.zeros_like(leaf[idx]))
        return jax.tree.map(z, c)

    blocks, rem = cache["layers"]
    blocks = tuple(zero_slot(c, 1) for c in blocks)     # (n_super, B, ...)
    rem = tuple(zero_slot(c, 0) for c in rem)           # (B, ...)
    out = dict(cache)
    out["layers"] = (blocks, rem)
    out["pos"] = cache["pos"].at[slot].set(0)
    return out


def decode_step_slots(params, cfg: ModelConfig, cache: Dict,
                      tokens: jax.Array, active: jax.Array):
    """One engine step over independent slots.

    tokens: (B, 1) int32 — per-slot next token (prompt token while the slot
    is prefilling, previously sampled token while decoding; ignored for
    inactive slots).  active: (B,) bool.  cache["pos"]: (B,) int32 per-slot
    positions.  Inactive slots keep their cache and position bit-for-bit.

    The per-slot math is exactly `decode_step`'s (same rope/write/mask ops,
    vectorized over `pos`), which is what makes continuous-batching outputs
    token-identical to the static replay path.
    """
    return _decode_step_impl(params, cfg, cache, tokens, active=active)


# ----------------- block-paged slot decode (serving, paged layout) ----------
def init_slot_cache_paged(cfg: ModelConfig, n_slots: int, max_seq: int, *,
                          block_size: int = 16,
                          total_blocks: Optional[int] = None) -> Dict:
    """Physically block-paged cache for the continuous-batching engine.

    Attention layers store K/V in one ``(total_blocks + 1, n_kv_heads,
    block_size, head_dim)`` arena per layer/K-V instead of dense
    ``(n_slots, heads, max_seq, head_dim)`` rows; a slot's sequence lives
    in the physical pages its ``block_tables`` row names (block ``j``
    holds positions ``[j * block_size, (j + 1) * block_size)``), so the
    pool can be provisioned for tokens-in-flight rather than
    ``n_slots x max_seq``.  The trailing arena page (index
    ``total_blocks``) is the *trash page*: inactive slots' writes are
    routed there, never read back (no block table names it).  Recurrent
    SSM states and cross-attention rows stay slot-major (they are O(1) in
    sequence length).  Layout is shared across layers — one table indexes
    every layer's arena.

    Sliding-window layers are not paged yet (the dense rolling buffer
    reuses slots in place; paging it needs in-kernel modular gather) —
    configs with ``attn_window`` must serve on the dense layout.
    """
    if cfg.attn_window is not None:
        raise ValueError(
            "paged KV layout does not support sliding-window attention "
            "(rolling-buffer slots); serve this config with the dense "
            "layout")
    unit = cfg.pattern_unit()
    blocks_per_slot = -(-max_seq // block_size)
    if total_blocks is None:
        total_blocks = n_slots * blocks_per_slot

    def one(btype):
        if btype in ("attn",):
            arena = jnp.zeros((total_blocks + 1, cfg.n_kv_heads, block_size,
                               cfg.hd), cfg.compute_dtype)
            return {"k": arena, "v": arena}
        if btype == "rec":
            return {"rec": ssm_lib.rglru_init_state(cfg.rglru_args(),
                                                    n_slots)}
        if btype == "mamba":
            return {"mamba": ssm_lib.mamba_init_state(cfg.mamba_args(),
                                                      n_slots)}
        if btype == "xattn":
            return jnp.zeros((), jnp.int32)
        raise ValueError(btype)

    def stacked(btype):
        c = one(btype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_super,) + a.shape), c)

    blocks = tuple(stacked(b) for b in unit)
    rem = tuple(one(unit[i % len(unit)]) for i in range(cfg.n_rem))
    cross = None
    if cfg.encoder_decoder:
        cross = jnp.zeros((n_slots, max_seq, cfg.d_model), cfg.compute_dtype)
    elif cfg.frontend == "vision":
        cross = jnp.zeros((n_slots, cfg.img_seq, cfg.d_model),
                          cfg.compute_dtype)
    return {"layers": (blocks, rem),
            "pos": jnp.zeros((n_slots,), jnp.int32),
            "cross": cross,
            "block_tables": jnp.zeros((n_slots, blocks_per_slot), jnp.int32)}


def _decode_attn_block_paged(p, x, cache, cfg: ModelConfig, pos,
                             cross_feats, block_tables, active, max_seq):
    """Paged counterpart of `_decode_attn_block`: identical q/k/v math
    (same rope over the same per-slot positions), but the new token's K/V
    is scattered into its slot's tail page and attention gathers through
    the block table.  Inactive slots' writes route to the trash page, so
    live pages are never clobbered (the dense path's `keep` select has no
    slot-major arena axis to apply to)."""
    h_in = rmsnorm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _project_qkv(p["attn"], h_in, cfg)
    pos_a = jnp.asarray(pos)
    assert pos_a.ndim == 1, "paged decode is per-slot (continuous batching)"
    posq = pos_a[:, None, None]
    q = apply_rope(q, posq, cfg.rope_theta)
    k = apply_rope(k, posq, cfg.rope_theta)

    b = x.shape[0]
    bs = cache["k"].shape[-2]
    nb = block_tables.shape[1]
    trash = cache["k"].shape[0] - 1
    j = jnp.clip(pos_a // bs, 0, nb - 1)
    off = pos_a % bs
    phys = block_tables[jnp.arange(b), j]
    phys = jnp.where(active, phys, trash)
    heads = jnp.arange(cfg.n_kv_heads)[None, :]
    k_arena = cache["k"].at[phys[:, None], heads, off[:, None]].set(
        k[:, :, 0, :].astype(cache["k"].dtype))
    v_arena = cache["v"].at[phys[:, None], heads, off[:, None]].set(
        v[:, :, 0, :].astype(cache["v"].dtype))

    out = attn_lib.paged_decode_attention(
        q, k_arena, v_arena, block_tables, pos_a, max_seq=max_seq,
        impl=cfg.paged_attention_impl)
    x = x + _merge_heads(out, p["attn"], cfg)
    if "xattn" in p and cross_feats is not None:
        x = x + _cross_attention(p, x, cross_feats, cfg)
    x = _mlp(p, x, cfg)
    return x, {"k": k_arena, "v": v_arena}


def decode_step_slots_paged(params, cfg: ModelConfig, cache: Dict,
                            tokens: jax.Array, active: jax.Array, *,
                            max_seq: int):
    """One engine step over independent slots on the block-paged cache.

    Same contract as :func:`decode_step_slots` — tokens (B, 1), active
    (B,) bool, per-slot ``cache["pos"]`` — plus ``cache["block_tables"]``
    (B, NB) naming each slot's physical pages.  ``max_seq`` (static) trims
    the gathered rows to the dense layout's sequence axis so outputs are
    BIT-IDENTICAL to `decode_step_slots` on the equivalent dense cache
    (asserted in tests/test_paged.py and the serving bench).  Inactive
    slots keep position, recurrent state and their live pages bit-for-bit
    (their KV write lands in the trash page).

    Everything except the attention block step is the one shared
    `_decode_step_impl` body the dense paths run."""
    pos = cache["pos"]
    cross_feats = cache.get("cross")
    block_tables = cache["block_tables"]

    def block_step(btype, p, h, c):
        if btype == "attn":
            return _decode_attn_block_paged(p, h, c, cfg, pos, cross_feats,
                                            block_tables, active, max_seq)
        return _decode_block(btype, p, h, c, cfg, pos, cross_feats)

    return _decode_step_impl(params, cfg, cache, tokens, active,
                             block_step=block_step, arena_passthrough=True)


def _multi_attn_block_paged(p, x, cache, cfg: ModelConfig, pos,
                            cross_feats, block_tables, active, max_seq):
    """Verification-window counterpart of `_decode_attn_block_paged`: x is
    (B, M, d) — M consecutive tokens per slot at positions pos..pos+M-1 —
    whose K/V is scattered into the slot's pages in one shot, and each
    query attends over cache slots <= its own position (the freshly
    written window prefix included, exactly as M sequential single-token
    steps would see it).  Inactive slots' writes route to the trash page;
    their offsets collide across slots there, which is harmless — the
    trash page is never attended."""
    h_in = rmsnorm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _project_qkv(p["attn"], h_in, cfg)             # (B,H,M,hd)
    pos_a = jnp.asarray(pos)
    assert pos_a.ndim == 1, "paged decode is per-slot (continuous batching)"
    b, m = x.shape[0], x.shape[1]
    wpos = pos_a[:, None] + jnp.arange(m)                    # (B, M)
    posq = wpos[:, None, :]
    q = apply_rope(q, posq, cfg.rope_theta)
    k = apply_rope(k, posq, cfg.rope_theta)

    bs = cache["k"].shape[-2]
    nb = block_tables.shape[1]
    trash = cache["k"].shape[0] - 1
    j = jnp.clip(wpos // bs, 0, nb - 1)
    off = wpos % bs
    phys = jnp.take_along_axis(block_tables, j, axis=1)      # (B, M)
    phys = jnp.where(active[:, None], phys, trash)
    heads = jnp.arange(cfg.n_kv_heads)[None, None, :]
    # window positions are distinct per slot and slots own disjoint pages,
    # so the M-way scatter has no live-page collisions
    k_arena = cache["k"].at[phys[:, :, None], heads, off[:, :, None]].set(
        k.transpose(0, 2, 1, 3).astype(cache["k"].dtype))
    v_arena = cache["v"].at[phys[:, :, None], heads, off[:, :, None]].set(
        v.transpose(0, 2, 1, 3).astype(cache["v"].dtype))

    out = attn_lib.paged_decode_attention_multi(
        q, k_arena, v_arena, block_tables, pos_a, max_seq=max_seq)
    x = x + _merge_heads(out, p["attn"], cfg)
    if "xattn" in p and cross_feats is not None:
        x = x + _cross_attention(p, x, cross_feats, cfg)
    x = _mlp(p, x, cfg)
    return x, {"k": k_arena, "v": v_arena}


def decode_multi_step_slots_paged(params, cfg: ModelConfig, cache: Dict,
                                  tokens: jax.Array, active: jax.Array, *,
                                  max_seq: int, advance: bool = True):
    """M-token engine step over independent slots on the block-paged cache.

    tokens: (B, M) int32 — M *consecutive* chain tokens per slot starting
    at ``cache["pos"]`` — against the same cache contract as
    :func:`decode_step_slots_paged`.  Returns (logits (B, M, V), cache):
    logits[:, i] conditions on tokens[:, :i+1], so feeding the committed
    head plus k drafted tokens yields the target's greedy continuation at
    every window offset in ONE step — speculative verification — and
    feeding a prompt chunk replays prefill M tokens at a time (draft
    enrollment).

    ``advance`` (static): True moves each active slot's position by M (the
    enrollment/replay feed); False leaves ``pos`` untouched so the caller
    can commit only the accepted prefix (speculative verify).  Positions
    pos+c..pos+M-1 then hold *stale* K/V from the rejected tail — safe
    because every later feed starts at the committed position and rewrites
    forward before attention ever reaches them (attention masks
    kv_slot <= query position).

    Requires an all-attention config: recurrent/SSM state advances
    token-serially and has no slot-local multi-token step.
    """
    if any(t != "attn" for t in cfg.layer_types()):
        raise ValueError(
            "multi-token slot step requires an all-attention config: "
            "recurrent/SSM layer state has no multi-token slot step")
    pos = cache["pos"]
    cross_feats = cache.get("cross")
    block_tables = cache["block_tables"]

    def block_step(btype, p, h, c):
        return _multi_attn_block_paged(p, h, c, cfg, pos, cross_feats,
                                       block_tables, active, max_seq)

    return _decode_step_impl(
        params, cfg, cache, tokens, active, block_step=block_step,
        arena_passthrough=True,
        pos_increment=tokens.shape[1] if advance else 0)


# ---------------------------------------------------------------------------
# Accounting (roofline MODEL_FLOPS)
# ---------------------------------------------------------------------------
def count_params(cfg: ModelConfig) -> int:
    d, h, hk, hd, f = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.d_ff
    attn = d * h * hd + 2 * d * hk * hd + h * hd * d
    if cfg.qkv_bias:
        attn += h * hd + 2 * hk * hd
    mats = 3 if cfg.gated_mlp else 2
    if cfg.n_experts > 0:
        mlp = cfg.n_experts * mats * d * f + d * cfg.n_experts
    else:
        mlp = mats * d * f
    ma = cfg.mamba_args()
    mamba = (d * 2 * ma.d_inner + ma.d_inner * d + ma.d_inner * ma.d_conv
             + ma.d_inner * (ma.dt_rank + 2 * ma.d_state)
             + ma.dt_rank * ma.d_inner + ma.d_inner * ma.d_state + ma.d_inner)
    ra = cfg.rglru_args()
    rec = (d * 2 * ra.d_inner + ra.d_inner * d + 2 * ra.d_inner * ra.d_inner
           + 2 * ra.d_inner + ra.d_inner * ra.d_conv)
    per_type = {"attn": attn + mlp, "xattn": attn + mlp, "rec": rec + mlp,
                "mamba": mamba}
    total = sum(per_type[t] for t in cfg.layer_types())
    total += cfg.vocab * d
    if not cfg.tie_embeddings:
        total += d * cfg.vocab
    if cfg.encoder_decoder:
        total += (cfg.enc_layers or cfg.n_layers) * (attn + mlp)
        total += cfg.n_layers * attn          # decoder cross-attention
    return total


def count_active_params(cfg: ModelConfig) -> int:
    """MoE: only top_k experts per token count toward 6·N·D."""
    if cfg.n_experts == 0:
        return count_params(cfg)
    full = count_params(cfg)
    d, f = cfg.d_model, cfg.d_ff
    mats = 3 if cfg.gated_mlp else 2
    inactive = (cfg.n_experts - cfg.moe_top_k) * mats * d * f
    return full - len([t for t in cfg.layer_types() if t in ("attn", "xattn")]
                      ) * inactive
