"""AlexNet (paper Table I) as a CNNLab application.

The network is declared as layer tuples (core.layer_model.alexnet_full_spec),
scheduled by the CNNLab middleware onto execution engines, and compiled into
one jit program.  This is the paper's own experimental model, used by
examples/cnnlab_alexnet.py and the Fig. 6 benchmarks.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from ..core import engines as eng
from ..core import plan as plan_lib
from ..core import scheduler as sched
from ..core.layer_model import NetworkSpec, alexnet_full_spec


class AlexNet:
    """Schedulable AlexNet.  objective/engines pick the execution mapping."""

    def __init__(self, *, objective: str = "latency",
                 engines: Sequence[eng.ExecutionEngine] = eng.DEFAULT_ENGINES,
                 net: Optional[NetworkSpec] = None):
        self.net = net or alexnet_full_spec()
        self.plan = sched.schedule(self.net, engines, objective=objective)
        self._apply = plan_lib.compile_plan(self.plan)

    def init(self, key: jax.Array, dtype=jnp.float32) -> List[Dict]:
        return plan_lib.init_network_params(self.net, key, dtype)

    def __call__(self, x: jax.Array, params: List[Dict]) -> jax.Array:
        return self._apply(x, params)

    def loss(self, params: List[Dict], x: jax.Array,
             labels: jax.Array) -> jax.Array:
        probs = self._apply(x, params)
        logp = jnp.log(jnp.maximum(probs, 1e-9))
        return -jnp.mean(
            jnp.take_along_axis(logp, labels[:, None], axis=1))
