"""State-space blocks: Mamba-1 (falcon-mamba) and RG-LRU (recurrentgemma).

TPU adaptation of the CUDA selective-scan: a *chunked associative scan* —
sequential `lax.scan` over length-`chunk` segments (so the (B, L, d_inner,
d_state) tensor is never materialized; peak transient is (B, chunk, d_inner,
d_state)), with `jax.lax.associative_scan` inside each segment for
log-depth parallelism on the VPU, and `jax.checkpoint` on the segment body
so the backward pass recomputes segment internals from the carried state —
the same recompute trade the CUDA kernel makes.

Both recurrences are diagonal, so d_inner shards over the 'model' mesh axis
with zero collectives inside the scan.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Mamba-1
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MambaArgs:
    d_model: int
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return math.ceil(self.d_model / 16)


def init_mamba_params(key: jax.Array, args: MambaArgs,
                      dtype=jnp.float32) -> Dict[str, jax.Array]:
    ks = jax.random.split(key, 6)
    d, di, n, r = args.d_model, args.d_inner, args.d_state, args.dt_rank
    s = (2.0 / d) ** 0.5
    a = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))
    return {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * di)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (args.d_conv, di)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": (jax.random.normal(ks[2], (di, r + 2 * n)) * (2.0 / di) ** 0.5).astype(dtype),
        "dt_proj": (jax.random.normal(ks[3], (r, di)) * (2.0 / r) ** 0.5).astype(dtype),
        "dt_bias": jnp.full((di,), -4.6, dtype),   # softplus^-1(0.01)
        "A_log": jnp.log(a).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "out_proj": (jax.random.normal(ks[4], (di, d)) * (2.0 / di) ** 0.5).astype(dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv over L.  x: (B, L, DI); w: (K, DI).
    `state`: (B, K-1, DI) trailing context from the previous call (decode).
    Returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)          # (B, L+K-1, DI)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):, :]
    return y + b, new_state


def _segment_scan(dA: jax.Array, dBx: jax.Array, h0: jax.Array
                  ) -> Tuple[jax.Array, jax.Array]:
    """Diagonal linear recurrence h_t = dA_t h_{t-1} + dBx_t over axis 1.
    dA/dBx: (B, C, DI, N); h0: (B, DI, N).  Returns (h_all, h_last)."""
    def combine(a, b):
        a_a, a_b = a
        b_a, b_b = b
        return (b_a * a_a, b_a * a_b + b_b)
    aa, hh = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    h_all = hh + aa * h0[:, None]
    return h_all, h_all[:, -1]


def mamba_apply(params: Dict[str, jax.Array], x: jax.Array, args: MambaArgs,
                compute_dtype=jnp.bfloat16, return_state: bool = False):
    """x: (B, L, D) -> (B, L, D); full-sequence (training / prefill body).
    With return_state, also returns the decode state (h, conv) so prefill
    hands a serve-ready cache to decode_step."""
    b, L, d = x.shape
    di, n, r = args.d_inner, args.d_state, args.dt_rank
    xz = jnp.dot(x.astype(compute_dtype), params["in_proj"].astype(compute_dtype))
    xc_pre, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _causal_conv(xc_pre,
                                  params["conv_w"].astype(compute_dtype),
                                  params["conv_b"].astype(compute_dtype))
    xc = jax.nn.silu(xc)

    dbc = jnp.dot(xc, params["x_proj"].astype(compute_dtype))
    dt, Bm, Cm = jnp.split(dbc, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.dot(dt, params["dt_proj"].astype(compute_dtype))
        + params["dt_bias"].astype(compute_dtype)).astype(jnp.float32)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))        # (DI, N)

    chunk = min(args.chunk, L)
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk

    def seg(h0, inp):
        xc_c, dt_c, B_c, C_c = inp                           # (B, C, ...)
        dA = jnp.exp(dt_c[..., None] * A)                    # (B, C, DI, N)
        dBx = (dt_c * xc_c.astype(jnp.float32))[..., None] * \
            B_c.astype(jnp.float32)[:, :, None, :]
        h_all, h_last = _segment_scan(dA, dBx, h0)
        y = jnp.einsum("bcdn,bcn->bcd", h_all,
                       C_c.astype(jnp.float32))              # (B, C, DI)
        return h_last, y.astype(compute_dtype)

    seg = jax.checkpoint(seg)
    h0 = jnp.zeros((b, di, n), jnp.float32)
    xs = (xc.reshape(b, nc, chunk, di).swapaxes(0, 1),
          dt.reshape(b, nc, chunk, di).swapaxes(0, 1),
          Bm.reshape(b, nc, chunk, n).swapaxes(0, 1),
          Cm.reshape(b, nc, chunk, n).swapaxes(0, 1))
    h_last, ys = jax.lax.scan(seg, h0, xs)
    y = ys.swapaxes(0, 1).reshape(b, L, di)
    y = y + params["D"].astype(compute_dtype) * xc
    y = y * jax.nn.silu(z)
    out = jnp.dot(y, params["out_proj"].astype(compute_dtype)).astype(x.dtype)
    if return_state:
        return out, {"h": h_last,
                     "conv": conv_state.astype(jnp.bfloat16)}
    return out


def mamba_init_state(args: MambaArgs, batch: int) -> Dict[str, jax.Array]:
    return {
        "h": jnp.zeros((batch, args.d_inner, args.d_state), jnp.float32),
        "conv": jnp.zeros((batch, args.d_conv - 1, args.d_inner), jnp.bfloat16),
    }


def mamba_step(params: Dict[str, jax.Array], x: jax.Array,
               state: Dict[str, jax.Array], args: MambaArgs,
               compute_dtype=jnp.bfloat16
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token decode.  x: (B, 1, D)."""
    r, n = args.dt_rank, args.d_state
    xz = jnp.dot(x.astype(compute_dtype), params["in_proj"].astype(compute_dtype))
    xc, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _causal_conv(
        xc, params["conv_w"].astype(compute_dtype),
        params["conv_b"].astype(compute_dtype),
        state["conv"].astype(compute_dtype))
    xc = jax.nn.silu(xc)
    dbc = jnp.dot(xc, params["x_proj"].astype(compute_dtype))
    dt, Bm, Cm = jnp.split(dbc, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.dot(dt, params["dt_proj"].astype(compute_dtype))
        + params["dt_bias"].astype(compute_dtype)).astype(jnp.float32)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt[:, 0, :, None] * A)                      # (B, DI, N)
    dBx = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * \
        Bm[:, 0].astype(jnp.float32)[:, None, :]
    h = dA * state["h"] + dBx
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0].astype(jnp.float32))[:, None]
    y = y.astype(compute_dtype) + params["D"].astype(compute_dtype) * xc
    y = y * jax.nn.silu(z)
    out = jnp.dot(y, params["out_proj"].astype(compute_dtype)).astype(x.dtype)
    return out, {"h": h, "conv": conv_state.astype(jnp.bfloat16)}


# ---------------------------------------------------------------------------
# RG-LRU (Griffin / recurrentgemma recurrent block)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RGLRUArgs:
    d_model: int
    d_conv: int = 4
    expand: int = 1       # recurrentgemma: lru_width == d_model
    c: float = 8.0
    chunk: int = 512

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model


def init_rglru_params(key: jax.Array, args: RGLRUArgs,
                      dtype=jnp.float32) -> Dict[str, jax.Array]:
    ks = jax.random.split(key, 6)
    d, di = args.d_model, args.d_inner
    s = (2.0 / d) ** 0.5
    si = (2.0 / di) ** 0.5
    # Λ init so a = σ(Λ)^c spreads over (0.9, 0.999)
    u = jax.random.uniform(ks[5], (di,), minval=0.9, maxval=0.999)
    lam = jnp.log(u ** (1.0 / args.c) / (1 - u ** (1.0 / args.c)))
    return {
        "x_proj": (jax.random.normal(ks[0], (d, di)) * s).astype(dtype),
        "gate_proj": (jax.random.normal(ks[1], (d, di)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[2], (args.d_conv, di)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "w_a": (jax.random.normal(ks[3], (di, di)) * si).astype(dtype),
        "b_a": jnp.zeros((di,), dtype),
        "w_i": (jax.random.normal(ks[4], (di, di)) * si).astype(dtype),
        "b_i": jnp.zeros((di,), dtype),
        "lambda": lam.astype(dtype),
        "out_proj": (jax.random.normal(ks[5], (di, d)) * si).astype(dtype),
    }


def _rglru_gates(params, xc, args, compute_dtype):
    r = jax.nn.sigmoid(jnp.dot(xc, params["w_a"].astype(compute_dtype))
                       + params["b_a"].astype(compute_dtype)).astype(jnp.float32)
    i = jax.nn.sigmoid(jnp.dot(xc, params["w_i"].astype(compute_dtype))
                       + params["b_i"].astype(compute_dtype)).astype(jnp.float32)
    log_a1 = -jax.nn.softplus(-params["lambda"].astype(jnp.float32))  # log σ(Λ)
    log_a = args.c * r * log_a1                                       # ≤ 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    return a, beta * i * xc.astype(jnp.float32)


def rglru_apply(params: Dict[str, jax.Array], x: jax.Array, args: RGLRUArgs,
                compute_dtype=jnp.bfloat16, return_state: bool = False):
    """Full-sequence RG-LRU branch.  x: (B, L, D) -> (B, L, D)."""
    b, L, _ = x.shape
    xc = jnp.dot(x.astype(compute_dtype), params["x_proj"].astype(compute_dtype))
    xc, conv_state = _causal_conv(xc, params["conv_w"].astype(compute_dtype),
                                  params["conv_b"].astype(compute_dtype))
    a, bx = _rglru_gates(params, xc, args, compute_dtype)

    chunk = min(args.chunk, L)
    assert L % chunk == 0
    nc = L // chunk

    def seg(h0, inp):
        a_c, bx_c = inp
        h_all, h_last = _segment_scan(a_c[..., None], bx_c[..., None], h0[..., None])
        return h_last[..., 0], h_all[..., 0].astype(compute_dtype)

    seg = jax.checkpoint(seg)
    h0 = jnp.zeros((b, args.d_inner), jnp.float32)
    xs = (a.reshape(b, nc, chunk, -1).swapaxes(0, 1),
          bx.reshape(b, nc, chunk, -1).swapaxes(0, 1))
    h_last, ys = jax.lax.scan(seg, h0, xs)
    h = ys.swapaxes(0, 1).reshape(b, L, args.d_inner)

    gate = jax.nn.gelu(jnp.dot(x.astype(compute_dtype),
                               params["gate_proj"].astype(compute_dtype)))
    y = h * gate
    out = jnp.dot(y, params["out_proj"].astype(compute_dtype)).astype(x.dtype)
    if return_state:
        return out, {"h": h_last, "conv": conv_state.astype(jnp.bfloat16)}
    return out


def rglru_init_state(args: RGLRUArgs, batch: int) -> Dict[str, jax.Array]:
    return {
        "h": jnp.zeros((batch, args.d_inner), jnp.float32),
        "conv": jnp.zeros((batch, args.d_conv - 1, args.d_inner), jnp.bfloat16),
    }


def rglru_step(params: Dict[str, jax.Array], x: jax.Array,
               state: Dict[str, jax.Array], args: RGLRUArgs,
               compute_dtype=jnp.bfloat16
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token decode.  x: (B, 1, D)."""
    xc = jnp.dot(x.astype(compute_dtype), params["x_proj"].astype(compute_dtype))
    xc, conv_state = _causal_conv(
        xc, params["conv_w"].astype(compute_dtype),
        params["conv_b"].astype(compute_dtype),
        state["conv"].astype(compute_dtype))
    a, bx = _rglru_gates(params, xc, args, compute_dtype)
    h = a[:, 0] * state["h"] + bx[:, 0]
    gate = jax.nn.gelu(jnp.dot(x.astype(compute_dtype),
                               params["gate_proj"].astype(compute_dtype)))
    y = h[:, None].astype(compute_dtype) * gate
    out = jnp.dot(y, params["out_proj"].astype(compute_dtype)).astype(x.dtype)
    return out, {"h": h, "conv": conv_state.astype(jnp.bfloat16)}
