"""Logical-axis sharding rules → NamedSharding, with divisibility fallbacks.

The production mesh is (data=16, model=16) per pod, with an outer 'pod' axis
across pods.  Policy:

* **TP mode** (head count divides the 'model' axis): heads/d_ff/experts
  shard over 'model'; batch over ('pod','data'); params FSDP over 'data'
  on their d_model/vocab dimension (ZeRO-style).
* **FSDP/SP mode** (heads don't divide — deepseek 56H, minicpm 36H, qwen2
  12H, recurrentgemma 10H): activations shard *sequence* over 'model'
  (context parallelism — compute stays balanced on every chip), params
  shard over both 'data' and 'model' purely for storage, and XLA GSPMD
  inserts the per-layer all-gathers (ZeRO-3 semantics).

Every rule is a *priority list* of mesh axes; the resolver takes the first
candidate whose size divides the dimension and that isn't already used by
another dimension of the same array — this is what makes all 40
(arch × shape) cells lower with zero per-cell hand-tuning (e.g. mixtral's
8 kv-heads on a 16-way axis fall back to replicated kv, granite's kv=1
likewise, phi3.5's 16 experts take 'model' for true EP).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .transformer import ModelConfig

PyTree = Any

# logical axis -> ordered candidate mesh-axis tuples ((..) may fuse axes)
RULES: Dict[str, Sequence[Tuple[str, ...]]] = {
    "batch":      [("pod", "data"), ("data",), ("pod",), ()],
    "seq":        [("model",), ()],          # only consulted in fsdp/sp mode
    "heads":      [("model",), ()],
    "kv_heads":   [("model",), ()],
    "ff":         [("model",), ()],
    "expert":     [("model",), ()],
    "d_inner":    [("model",), ()],
    "vocab":      [("model",), ()],
    "embed":      [("data",), ()],            # param FSDP dim
    "embed2":     [("model",), ()],            # ZeRO-3 second storage dim
    "cache_time": [("model",), ()],
    "cache_batch": [("data",), ("pod",), ()],
    "replicated": [()],
}

# resolution priority: most contended axes first
_PRIORITY = ["expert", "heads", "kv_heads", "ff", "d_inner", "vocab", "seq",
             "cache_time", "batch", "cache_batch", "embed", "embed2",
             "replicated"]


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    mesh: Mesh
    tp_mode: bool          # True -> TP; False -> FSDP/SP fallback
    zero3: bool = False    # shard params over 'model' too (fsdp mode)
    # SSM archs: the recurrence is sequential in S, so sequence sharding
    # would serialize across shards — instead shard batch over the WHOLE
    # mesh (pure DP + ZeRO storage).  train_4k's batch=256 covers all 256
    # chips of a pod exactly.
    pure_dp: bool = False

    def axis_size(self, name: str) -> int:
        return self.mesh.shape.get(name, 1)

    def resolve(self, shape: Tuple[int, ...],
                names: Sequence[Optional[str]]) -> P:
        assert len(shape) == len(names), (shape, names)
        chosen: Dict[int, Tuple[str, ...]] = {}
        used: set = set()
        order = sorted(range(len(names)),
                       key=lambda i: _PRIORITY.index(names[i])
                       if names[i] in _PRIORITY else 99)
        for i in order:
            ln = names[i]
            if ln is None or ln not in RULES:
                continue
            # 'seq' shards over 'model' in both attention modes (Megatron-
            # style sequence parallelism on the residual stream: the scan
            # carry is (B, S/16, D) instead of (B, S, D); attention/FFN
            # internals re-shard per the param specs and GSPMD inserts the
            # boundary all-gather / reduce-scatter pairs)
            if ln == "embed2" and not self.zero3:
                continue
            rules = RULES[ln]
            if self.pure_dp:
                if ln == "seq":
                    continue
                if ln == "batch":
                    rules = [("pod", "data", "model"), ("data", "model"),
                             ("pod", "data"), ("data",), ()]
            for cand in rules:
                if not cand:
                    break                                   # explicit no-shard
                if any(a not in self.mesh.shape for a in cand):
                    continue                                # axis not in mesh
                if any(a in used for a in cand):
                    continue                                # axis taken
                size = 1
                for a in cand:
                    size *= self.mesh.shape[a]
                if size > 1 and shape[i] % size == 0:
                    chosen[i] = cand
                    used.update(cand)
                    break
        parts = []
        for i in range(len(shape)):
            c = chosen.get(i, ())
            parts.append(c[0] if len(c) == 1 else (c if c else None))
        return P(*parts)

    def named(self, shape: Tuple[int, ...],
              names: Sequence[Optional[str]]) -> NamedSharding:
        spec = self.resolve(shape, names)
        if not isinstance(self.mesh, Mesh):     # mocked mesh (unit tests)
            return spec
        return NamedSharding(self.mesh, spec)


def make_policy(cfg: ModelConfig, mesh: Mesh) -> ShardingPolicy:
    tp = cfg.n_heads == 0 or cfg.n_heads % mesh.shape.get("model", 1) == 0
    pure_dp = any(t in ("mamba", "rec") for t in cfg.layer_types())
    return ShardingPolicy(mesh=mesh, tp_mode=tp, zero3=not tp,
                          pure_dp=pure_dp)


# ---------------------------------------------------------------------------
# Logical names for every param in the transformer tree
# ---------------------------------------------------------------------------
def _attn_names(cfg: ModelConfig, stacked: bool) -> Dict:
    L = ["layers"] if stacked else []
    n = {
        "wq": L + ["embed", "heads"],
        "wk": L + ["embed", "kv_heads"],
        "wv": L + ["embed", "kv_heads"],
        "wo": L + ["heads", "embed"],
    }
    if cfg.qkv_bias:
        n["bq"] = L + ["heads"]
        n["bk"] = L + ["kv_heads"]
        n["bv"] = L + ["kv_heads"]
    return n


def _mlp_names(cfg: ModelConfig, stacked: bool) -> Dict:
    L = ["layers"] if stacked else []
    if cfg.n_experts > 0:
        return {
            "router": L + ["embed", "replicated"],
            "w_gate": L + ["expert", "embed", "ff"],
            "w_up": L + ["expert", "embed", "ff"],
            "w_down": L + ["expert", "ff", "embed"],
        }
    n = {
        "w_up": L + ["embed", "ff"],
        "w_down": L + ["ff", "embed"],
    }
    if cfg.gated_mlp:
        n["w_gate"] = L + ["embed", "ff"]
    return n


def _block_names(btype: str, cfg: ModelConfig, stacked: bool,
                 with_cross: bool) -> Dict:
    L = ["layers"] if stacked else []
    vec = L + ["replicated"]
    if btype == "attn":
        n = {"ln1": vec, "attn": _attn_names(cfg, stacked), "ln2": vec,
             "mlp": _mlp_names(cfg, stacked)}
        if with_cross:
            n["lnx"] = vec
            n["xattn"] = _attn_names(cfg, stacked)
        return n
    if btype == "xattn":
        return {"ln1": vec, "xattn": _attn_names(cfg, stacked), "ln2": vec,
                "mlp": _mlp_names(cfg, stacked),
                "gate_attn": list(L), "gate_mlp": list(L)}
    if btype == "rec":
        return {"ln1": vec,
                "rec": {
                    "x_proj": L + ["embed", "d_inner"],
                    "gate_proj": L + ["embed", "d_inner"],
                    "conv_w": L + ["replicated", "d_inner"],
                    "conv_b": L + ["d_inner"],
                    "w_a": L + ["embed2", "d_inner"],
                    "b_a": L + ["d_inner"],
                    "w_i": L + ["embed2", "d_inner"],
                    "b_i": L + ["d_inner"],
                    "lambda": L + ["d_inner"],
                    "out_proj": L + ["d_inner", "embed"],
                },
                "ln2": vec, "mlp": _mlp_names(cfg, stacked)}
    if btype == "mamba":
        return {"ln1": vec,
                "mamba": {
                    "in_proj": L + ["embed", "d_inner"],
                    "conv_w": L + ["replicated", "d_inner"],
                    "conv_b": L + ["d_inner"],
                    "x_proj": L + ["d_inner", "replicated"],
                    "dt_proj": L + ["replicated", "d_inner"],
                    "dt_bias": L + ["d_inner"],
                    "A_log": L + ["d_inner", "replicated"],
                    "D": L + ["d_inner"],
                    "out_proj": L + ["d_inner", "embed"],
                }}
    raise ValueError(btype)


def _stack_names(cfg: ModelConfig, with_cross: bool) -> Dict:
    unit = cfg.pattern_unit()
    return {
        "blocks": tuple(_block_names(b, cfg, True, with_cross) for b in unit),
        "rem": tuple(_block_names(unit[i % len(unit)], cfg, False, with_cross)
                     for i in range(cfg.n_rem)),
    }


def param_logical_names(cfg: ModelConfig) -> Dict:
    names: Dict[str, Any] = {
        "embed": ["vocab", "embed"],
        "final_norm": ["replicated"],
        "decoder": _stack_names(cfg, with_cross=cfg.encoder_decoder),
    }
    if not cfg.tie_embeddings:
        names["lm_head"] = ["embed", "vocab"]
    if cfg.encoder_decoder:
        enc_cfg = dataclasses.replace(
            cfg, n_layers=cfg.enc_layers or cfg.n_layers,
            block_pattern=("attn",), cross_attn_every=0, encoder_decoder=False)
        names["encoder"] = _stack_names(enc_cfg, with_cross=False)
        names["enc_final_norm"] = ["replicated"]
    return names


def _tree_shardings(tree_shapes: PyTree, tree_names: PyTree,
                    policy: ShardingPolicy) -> PyTree:
    def leafify(shape_leaf, names_leaf):
        shape = tuple(shape_leaf.shape)
        names = list(names_leaf)
        # leading 'layers' dim is the scan axis: never sharded
        resolved_names = [None if n == "layers" else n for n in names]
        # pad/truncate to rank (scalars, ())
        resolved_names = (resolved_names + [None] * len(shape))[:len(shape)]
        return policy.named(shape, resolved_names)

    return jax.tree.map(leafify, tree_shapes, tree_names)


def param_shardings(cfg: ModelConfig, policy: ShardingPolicy,
                    param_shapes: PyTree) -> PyTree:
    names = param_logical_names(cfg)
    return _tree_shardings(param_shapes, names, policy)


def batch_shardings(cfg: ModelConfig, policy: ShardingPolicy,
                    batch_shapes: Dict[str, Any]) -> Dict[str, Any]:
    out = {}
    for k, v in batch_shapes.items():
        if k in ("tokens", "labels", "mask"):
            out[k] = policy.named(tuple(v.shape), ["batch", "seq"])
        elif k in ("enc_inputs", "img_embeds"):
            out[k] = policy.named(tuple(v.shape), ["batch", "seq", None])
        else:
            out[k] = policy.named(tuple(v.shape), [None] * len(v.shape))
    return out


def cache_shardings(cfg: ModelConfig, policy: ShardingPolicy,
                    cache_shapes: PyTree) -> PyTree:
    """Caches: kv (L?, B, HK, T, D) — kv_heads over 'model' when divisible,
    else time over 'model'; batch over 'data'.  SSM states: d_inner over
    'model'.  Dispatches on the leaf's key name in the cache pytree."""
    def leaf(path, x):
        shape = tuple(x.shape)
        rank = len(shape)
        key = ""
        for p in reversed(path):
            if hasattr(p, "key"):
                key = p.key
                break
        if key in ("k", "v") and rank >= 4:
            lead = [None] * (rank - 4)
            return policy.named(shape, lead + ["cache_batch", "kv_heads",
                                               "cache_time", None])
        if key == "h" and rank >= 3 and shape[-1] == cfg.ssm_state:
            lead = [None] * (rank - 3)
            return policy.named(shape, lead + ["cache_batch", "d_inner", None])
        if key == "h" and rank >= 2:                       # rglru (B, DI)
            lead = [None] * (rank - 2)
            return policy.named(shape, lead + ["cache_batch", "d_inner"])
        if key == "conv" and rank >= 3:                    # (B, K-1, DI)
            lead = [None] * (rank - 3)
            return policy.named(shape, lead + ["cache_batch", None, "d_inner"])
        if key == "cross" and rank == 3:                   # (B, T, D)
            return policy.named(shape, ["cache_batch", "seq", None])
        return policy.named(shape, [None] * rank)

    return jax.tree_util.tree_map_with_path(leaf, cache_shapes)
