"""Attention implementations (the CNNLab 'engine' axis for transformers).

Three engines, selected per-layer by the scheduler / config:

* ``dot``      — plain masked dot-product attention (XLA).  O(S·T) score
                 materialization; right choice for short sequences.
* ``chunked``  — memory-efficient online-softmax attention in pure lax
                 (Rabe–Staats / flash algorithm as an XLA scan).  Portable to
                 any backend — this is what the multi-pod dry-run lowers —
                 and never materializes more than (bq, bk) scores per step.
* ``pallas``   — kernels/flash_attention.py (Mosaic on real TPUs).

All take q: (B, HQ, S, D), k/v: (B, HK, T, D) with HQ % HK == 0 and compute
GQA without repeating KV in memory.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..kernels import ops as kops

_NEG_INF = -1e30


def _bhint(t, batch_axes, dim=0):
    """Pin the batch dim of attention internals.  GSPMD drops the batch
    shard through some flash-bwd einsums on TP-mode archs (measured:
    (B_global, H, S, bk) f32 buffers on llama-3.2-vision train)."""
    if batch_axes is None:
        return t
    from jax.sharding import PartitionSpec as P
    spec = [None] * t.ndim
    spec[dim] = batch_axes
    return jax.lax.with_sharding_constraint(t, P(*spec))


def _gqa_fold(q, hk):
    b, hq, s, d = q.shape
    return q.reshape(b, hk, hq // hk, s, d)


def dot_attention(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None,
                  q_offset: int = 0) -> jax.Array:
    """q_offset: absolute position of q[..., 0, :] relative to k's start."""
    b, hq, s, d = q.shape
    hk, t = k.shape[1], k.shape[2]
    qg = _gqa_fold(q, hk).astype(jnp.float32)
    scale = 1.0 / (d ** 0.5)
    logits = jnp.einsum("bkgsd,bktd->bkgst", qg, k.astype(jnp.float32)) * scale
    qpos = jnp.arange(s) + q_offset
    kpos = jnp.arange(t)
    mask = jnp.ones((s, t), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, _NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", probs, v.astype(jnp.float32))
    return out.reshape(b, hq, s, d).astype(q.dtype)


def _chunk_mask(s, bk, ik, t_real, causal, window):
    qpos = jnp.arange(s)
    kpos = ik * bk + jnp.arange(bk)
    mask = jnp.broadcast_to(kpos[None, :] < t_real, (s, bk))  # kill padding
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    return mask


def _pad_kv(k, v, bk):
    t_real = k.shape[2]
    pad_t = (-t_real) % bk
    if pad_t:                                   # e.g. cross-attn over 6404
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_t), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_t), (0, 0)))
    return k, v, t_real


def _chunked_fwd(q, k, v, causal, window, kv_chunk, batch_axes=None):
    """Online-softmax forward.  Returns (out (B,HK,G,S,D) f32, lse)."""
    b, hq, s, d = q.shape
    hk = k.shape[1]
    g = hq // hk
    bk = min(kv_chunk, k.shape[2])
    k, v, t_real = _pad_kv(k, v, bk)
    nk = k.shape[2] // bk
    scale = 1.0 / (d ** 0.5)
    # per-chunk casts only: upcasting full K/V would hold an f32 copy of
    # the whole (B,HK,T,D) tensors alive across the scan
    qg = _gqa_fold(q, hk).astype(jnp.float32) * scale       # (B,HK,G,S,D)
    kc = k.reshape(b, hk, nk, bk, d)
    vc = v.reshape(b, hk, nk, bk, d)

    def step(carry, inputs):
        m, l, acc = carry
        kb, vb, ik = inputs                                  # (B,HK,bk,D)
        logits = jnp.einsum("bkgsd,bktd->bkgst", qg,
                            kb.astype(jnp.float32))          # (B,HK,G,S,bk)
        logits = _bhint(logits, batch_axes)
        mask = _chunk_mask(s, bk, ik, t_real, causal, window)
        logits = jnp.where(mask[None, None, None], logits, _NEG_INF)
        m_cur = jnp.max(logits, axis=-1, keepdims=True)
        m_new = jnp.maximum(m, m_cur)
        p = jnp.exp(logits - m_new) * mask[None, None, None]
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = _bhint(acc * alpha + jnp.einsum(
            "bkgst,bktd->bkgsd", p, vb.astype(jnp.float32)), batch_axes)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hk, g, s, 1), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hk, g, s, 1), jnp.float32)
    a0 = jnp.zeros((b, hk, g, s, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (jnp.moveaxis(kc, 2, 0), jnp.moveaxis(vc, 2, 0), jnp.arange(nk)))
    l_safe = jnp.where(l == 0.0, 1.0, l)
    out = acc / l_safe
    lse = jnp.where(l == 0.0, 0.0, m + jnp.log(l_safe))     # (B,HK,G,S,1)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _chunked_attention_cv(q, k, v, causal, window, kv_chunk, batch_axes):
    out, _ = _chunked_fwd(q, k, v, causal, window, kv_chunk, batch_axes)
    b, hq, s, d = q.shape
    return out.reshape(b, hq, s, d).astype(q.dtype)


def _cv_fwd(q, k, v, causal, window, kv_chunk, batch_axes):
    out, lse = _chunked_fwd(q, k, v, causal, window, kv_chunk, batch_axes)
    b, hq, s, d = q.shape
    res = (q, k, v, out.astype(q.dtype), lse)
    return out.reshape(b, hq, s, d).astype(q.dtype), res


def _cv_bwd(causal, window, kv_chunk, batch_axes, res, dout):
    """Flash backward: recompute p per chunk from (q, k, v, lse) — saves
    O(S) residuals instead of the inner scan's per-step carries (this is
    what keeps 32k-token training under the HBM budget)."""
    q, k, v, out_f, lse = res
    b, hq, s, d = q.shape
    hk = k.shape[1]
    g = hq // hk
    bk = min(kv_chunk, k.shape[2])
    k, v, t_real = _pad_kv(k, v, bk)
    nk = k.shape[2] // bk
    scale = 1.0 / (d ** 0.5)
    qg = _gqa_fold(q, hk).astype(jnp.float32) * scale        # (B,HK,G,S,D)
    kc = jnp.moveaxis(k.reshape(b, hk, nk, bk, d), 2, 0)
    vc = jnp.moveaxis(v.reshape(b, hk, nk, bk, d), 2, 0)
    dof = _gqa_fold(dout, hk).astype(jnp.float32)            # (B,HK,G,S,D)
    of = out_f.astype(jnp.float32)                           # already folded
    delta = jnp.sum(dof * of, axis=-1, keepdims=True)        # (B,HK,G,S,1)

    def step(dq_acc, inputs):
        kb, vb, ik = inputs
        kb = kb.astype(jnp.float32)
        vb = vb.astype(jnp.float32)
        logits = _bhint(jnp.einsum("bkgsd,bktd->bkgst", qg, kb), batch_axes)
        mask = _chunk_mask(s, bk, ik, t_real, causal, window)
        p = jnp.exp(jnp.where(mask[None, None, None], logits, _NEG_INF)
                    - lse) * mask[None, None, None]          # (B,HK,G,S,bk)
        dv_j = _bhint(jnp.einsum("bkgst,bkgsd->bktd", p, dof), batch_axes)
        dp = _bhint(jnp.einsum("bkgsd,bktd->bkgst", dof, vb), batch_axes)
        ds = p * (dp - delta)                                # d(logits)
        dq_acc = _bhint(dq_acc + jnp.einsum("bkgst,bktd->bkgsd", ds, kb)
                        * scale, batch_axes)
        dk_j = _bhint(jnp.einsum("bkgst,bkgsd->bktd", ds, qg), batch_axes)
        return dq_acc, (dk_j, dv_j)

    dq0 = jnp.zeros((b, hk, g, s, d), jnp.float32)
    dq, (dk_c, dv_c) = jax.lax.scan(
        step, dq0, (kc, vc, jnp.arange(nk)))
    dk = jnp.moveaxis(dk_c, 0, 2).reshape(b, hk, nk * bk, d)[:, :, :t_real]
    dv = jnp.moveaxis(dv_c, 0, 2).reshape(b, hk, nk * bk, d)[:, :, :t_real]
    return (dq.reshape(b, hq, s, d).astype(q.dtype),
            dk.astype(k.dtype), dv.astype(v.dtype))


_chunked_attention_cv.defvjp(_cv_fwd, _cv_bwd)


def chunked_attention(q, k, v, *, causal: bool = True,
                      window: Optional[int] = None,
                      q_chunk: int = 2048, kv_chunk: int = 2048,
                      batch_axes=None) -> jax.Array:
    """Flash algorithm as pure lax, with a flash custom-vjp backward.

    Forward: scan over KV chunks with online softmax — never materializes
    more than (B, H, S, kv_chunk) scores.  Backward: recomputes each chunk's
    probabilities from the saved log-sum-exp (the flash-attention backward),
    so AD saves O(S) residuals rather than per-chunk scan carries.
    """
    del q_chunk  # the q dimension stays batched; kept for API compat
    return _chunked_attention_cv(q, k, v, causal, window, kv_chunk,
                                 batch_axes)


def pallas_attention(q, k, v, *, causal: bool = True,
                     window: Optional[int] = None) -> jax.Array:
    return kops.flash_attention(q, k, v, causal=causal, window=window)


def decode_attention(q, k_cache, v_cache, *, pos: jax.Array,
                     window: Optional[int] = None) -> jax.Array:
    """Single-token attention against a cache.

    q: (B, HQ, 1, D); caches: (B, HK, T, D).  `pos` is the absolute position
    of the current token — a scalar shared by the batch, or a (B,) vector of
    per-slot positions (continuous-batching: each cache row belongs to a
    different request).  For windowed layers the cache is a rolling buffer
    of size T == window written at pos % T; validity = slot was written.
    """
    b, hq, _, d = q.shape
    hk, t = k_cache.shape[1], k_cache.shape[2]
    qg = _gqa_fold(q, hk)[:, :, :, 0]                        # (B,HK,G,D)
    scale = 1.0 / (d ** 0.5)
    # IMPORTANT: do NOT upcast the cache — einsum in cache dtype with fp32
    # accumulation.  An .astype(f32) on the cache gets loop-hoisted out of
    # the layer scan by XLA and materializes an f32 copy of the ENTIRE
    # stacked cache (measured: +5.6 GB/device on minicpm decode_32k).
    logits = jnp.einsum("bkgd,bktd->bkgt", qg.astype(k_cache.dtype),
                        k_cache,
                        preferred_element_type=jnp.float32) * scale
    slots = jnp.arange(t)
    pos_a = jnp.asarray(pos)
    cap = pos_a if window is None else jnp.minimum(pos_a, t - 1)
    valid = slots <= cap[..., None]          # (t,) scalar | (B, t) per-slot
    mask = (valid[None, None, None] if valid.ndim == 1
            else valid[:, None, None, :])
    logits = jnp.where(mask, logits, _NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgt,bktd->bkgd", probs.astype(v_cache.dtype),
                     v_cache, preferred_element_type=jnp.float32)
    return out.reshape(b, hq, 1, d).astype(q.dtype)


def paged_decode_attention(q, k_arena, v_arena, block_tables, pos, *,
                           max_seq: int, impl: str = "ref") -> jax.Array:
    """Single-token attention against a block-paged cache.

    q: (B, HQ, 1, D); arenas: (total_blocks + 1, HK, BS, D) — fixed-size
    physical KV pages plus a trailing trash page inactive slots write to;
    block_tables: (B, NB) int32 logical->physical page map; pos: (B,)
    per-slot absolute position of the current token.

    ``impl="ref"`` gathers the slot rows through the table and runs
    :func:`decode_attention` on them — bit-identical to the dense slot
    cache by construction (the gathered row equals the dense row at every
    attended position, and masked positions contribute exact zeros either
    way), which is the serving engine's correctness contract on CPU.
    ``impl="pallas"`` runs kernels/paged_attention.py, which gathers pages
    inside the kernel (online softmax — allclose, not bit-identical).
    """
    if impl == "pallas":
        out = kops.paged_attention(q, k_arena, v_arena, block_tables, pos,
                                   max_seq=max_seq)
        return out.astype(q.dtype)
    if impl != "ref":
        raise ValueError(f"unknown paged attention impl {impl!r}")
    from ..kernels.ref import paged_gather
    k = paged_gather(k_arena, block_tables, max_seq)
    v = paged_gather(v_arena, block_tables, max_seq)
    return decode_attention(q, k, v, pos=pos, window=None)


def decode_attention_multi(q, k_cache, v_cache, *, pos: jax.Array
                           ) -> jax.Array:
    """M-token verification attention against a cache.

    q: (B, HQ, M, D); caches: (B, HK, T, D).  ``pos`` is a (B,) vector of
    per-slot absolute positions of the FIRST query token: query m of slot b
    sits at position pos[b] + m and attends over cache slots <= pos[b] + m
    (the cache must already hold the window's K/V at positions
    pos..pos+M-1).  This is :func:`decode_attention` with a query axis —
    speculative verification feeds the k drafted tokens plus the committed
    chain head in one step instead of k+1 sequential single-token steps.
    Windowed layers are unsupported: the rolling buffer's write-back
    overlaps itself inside one multi-token window.
    """
    b, hq, m, d = q.shape
    hk, t = k_cache.shape[1], k_cache.shape[2]
    qg = _gqa_fold(q, hk)                                    # (B,HK,G,M,D)
    scale = 1.0 / (d ** 0.5)
    # same no-upcast discipline as decode_attention (see the comment there)
    logits = jnp.einsum("bkgmd,bktd->bkgmt", qg.astype(k_cache.dtype),
                        k_cache,
                        preferred_element_type=jnp.float32) * scale
    slots = jnp.arange(t)
    qpos = jnp.asarray(pos)[:, None] + jnp.arange(m)         # (B, M)
    valid = slots[None, None] <= qpos[:, :, None]            # (B, M, T)
    logits = jnp.where(valid[:, None, None], logits, _NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgmt,bktd->bkgmd", probs.astype(v_cache.dtype),
                     v_cache, preferred_element_type=jnp.float32)
    return out.reshape(b, hq, m, d).astype(q.dtype)


def paged_decode_attention_multi(q, k_arena, v_arena, block_tables, pos, *,
                                 max_seq: int) -> jax.Array:
    """M-token verification attention against a block-paged cache.

    Shapes as :func:`paged_decode_attention` with q: (B, HQ, M, D).  Only
    the reference gather path exists: verification reuses the serving
    engine's bit-identity contract (gathered rows equal dense rows at
    every attended position), and a fused multi-query Pallas kernel is a
    follow-on once speculation runs on a real TPU.
    """
    from ..kernels.ref import paged_gather
    k = paged_gather(k_arena, block_tables, max_seq)
    v = paged_gather(v_arena, block_tables, max_seq)
    return decode_attention_multi(q, k, v, pos=pos)


ATTENTION_ENGINES = {
    "dot": dot_attention,
    "chunked": chunked_attention,
    "pallas": pallas_attention,
}


def attend(q, k, v, *, impl: str = "dot", causal: bool = True,
           window: Optional[int] = None, q_chunk: int = 2048,
           kv_chunk: int = 2048, batch_axes=None) -> jax.Array:
    if impl == "chunked":
        return chunked_attention(q, k, v, causal=causal, window=window,
                                 q_chunk=q_chunk, kv_chunk=kv_chunk,
                                 batch_axes=batch_axes)
    if impl == "pallas":
        return pallas_attention(q, k, v, causal=causal, window=window)
    return dot_attention(q, k, v, causal=causal, window=window)
