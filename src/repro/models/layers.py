"""Shared model layers: norms, RoPE, embeddings, losses, init helpers.

Everything is a pure function over explicit param pytrees (no flax) so the
same code paths serve jit/pjit tracing, eval_shape-based abstract init for
the dry-run, and checkpointing.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def truncated_normal_init(key, shape, scale: float, dtype=jnp.float32):
    # abstract-safe: works under eval_shape
    return jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) \
        .astype(dtype) * scale


def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def layernorm(x: jax.Array, weight: jax.Array, bias: jax.Array,
              eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def rope_freqs(head_dim: int, theta: float = 1e4) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 1e4
               ) -> jax.Array:
    """x: (..., S, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, D/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array, compute_dtype=jnp.bfloat16) -> jax.Array:
    h = jax.nn.silu(jnp.dot(x, w_gate.astype(compute_dtype))) * jnp.dot(
        x, w_up.astype(compute_dtype))
    return jnp.dot(h, w_down.astype(compute_dtype))


def gelu_mlp(x: jax.Array, w_in: jax.Array, b_in: Optional[jax.Array],
             w_out: jax.Array, b_out: Optional[jax.Array],
             compute_dtype=jnp.bfloat16) -> jax.Array:
    h = jnp.dot(x, w_in.astype(compute_dtype))
    if b_in is not None:
        h = h + b_in.astype(compute_dtype)
    h = jax.nn.gelu(h)
    out = jnp.dot(h, w_out.astype(compute_dtype))
    if b_out is not None:
        out = out + b_out.astype(compute_dtype)
    return out


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token CE.  logits: (..., V) fp32-upcast; labels: int (...)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
