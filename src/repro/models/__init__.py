"""Model substrate: unified transformer family + AlexNet CNN + sharding."""
from .transformer import (ModelConfig, count_active_params, count_params,  # noqa
                          decode_step, forward, init_cache, init_params,
                          loss_fn)
