"""Mixture-of-Experts FFN (mixtral, phi3.5-moe) with expert parallelism.

Capacity-based routing (GShard semantics: per-sequence capacity, over-cap
tokens dropped) implemented with scatter/gather instead of the classic
one-hot dispatch einsum:

    slot(token, k) = expert_id · C + position-within-expert
    buffers        = segment-sum of tokens into (B, E·C, D)
    experts        = batched FFN over (E, B, C, D)
    output         = gather back + gate-weighted sum over k

The classic einsum dispatch materializes a (B, S, E, C) tensor — O(B·S²)
memory and ~12% extra FLOPs at S=4k; the scatter form is linear in S and
adds no matmul FLOPs, so HLO FLOPs ≈ active expert FLOPs (clean 'useful
ratio' in §Roofline).  Experts shard over 'model' when E divides the axis
(phi3.5: 16e); otherwise d_ff shards over 'model' (mixtral: 8e on 16).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEArgs:
    d_model: int
    d_ff: int
    n_experts: int
    top_k: int = 2
    capacity_factor: float = 1.25


def init_moe_params(key: jax.Array, args: MoEArgs,
                    dtype=jnp.float32) -> Dict[str, jax.Array]:
    kr, kg, ku, kd = jax.random.split(key, 4)
    e, d, f = args.n_experts, args.d_model, args.d_ff
    s_in = (2.0 / d) ** 0.5
    s_out = (2.0 / f) ** 0.5
    return {
        "router": (jax.random.normal(kr, (d, e), jnp.float32) * s_in).astype(dtype),
        "w_gate": (jax.random.normal(kg, (e, d, f), jnp.float32) * s_in).astype(dtype),
        "w_up": (jax.random.normal(ku, (e, d, f), jnp.float32) * s_in).astype(dtype),
        "w_down": (jax.random.normal(kd, (e, f, d), jnp.float32) * s_out).astype(dtype),
    }


def capacity(args: MoEArgs, seq: int) -> int:
    c = int(seq * args.top_k / args.n_experts * args.capacity_factor)
    return max(8, ((c + 7) // 8) * 8)  # 8-aligned for TPU lanes


def _hint(t: jax.Array, batch_axes, dim: int):
    """Pin the batch dim of an MoE buffer: GSPMD's scatter sharding rules
    lose the batch shard through segment_sum (measured: expert buffers
    replicated to the full global batch, +30 GB/device on mixtral train)."""
    if batch_axes is None:
        return t
    from jax.sharding import PartitionSpec as P
    spec = [None] * t.ndim
    spec[dim] = batch_axes
    return jax.lax.with_sharding_constraint(t, P(*spec))


def moe_apply(params: Dict[str, jax.Array], x: jax.Array, args: MoEArgs,
              compute_dtype=jnp.bfloat16, batch_axes=None) -> jax.Array:
    """x: (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    e, k = args.n_experts, args.top_k
    cap = capacity(args, s)
    n_slots = e * cap

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)             # (B, S, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # position of each routing slot within its expert's capacity buffer,
    # counted over the flattened (S·K) slots of each sequence
    onehot = jax.nn.one_hot(gate_idx.reshape(b, s * k), e,
                            dtype=jnp.int32)                  # (B, S·K, E)
    pos = jnp.cumsum(onehot, axis=1) - onehot                 # (B, S·K, E)
    pos_sel = jnp.sum(pos * onehot, axis=-1)                  # (B, S·K)
    keep = pos_sel < cap
    flat_idx = gate_idx.reshape(b, s * k) * cap + pos_sel     # (B, S·K)
    flat_idx = jnp.where(keep, flat_idx, n_slots)             # dump slot

    # scatter tokens into expert buffers: (B, E·C(+dump), D)
    src = jnp.broadcast_to(x[:, :, None, :], (b, s, k, d)) \
        .reshape(b, s * k, d).astype(compute_dtype)
    seg = jax.vmap(functools.partial(jax.ops.segment_sum,
                                     num_segments=n_slots + 1))
    buf = _hint(seg(src, flat_idx)[:, :n_slots], batch_axes, 0)  # (B, E·C, D)
    xin = buf.reshape(b, e, cap, d).transpose(1, 0, 2, 3)     # (E, B, C, D)
    xin = _hint(xin, batch_axes, 1)

    h = jax.nn.silu(jnp.einsum("ebcd,edf->ebcf", xin,
                               params["w_gate"].astype(compute_dtype)))
    h = h * jnp.einsum("ebcd,edf->ebcf", xin,
                       params["w_up"].astype(compute_dtype))
    h = _hint(h, batch_axes, 1)
    y = jnp.einsum("ebcf,efd->ebcd", h,
                   params["w_down"].astype(compute_dtype))    # (E, B, C, D)
    y = _hint(y, batch_axes, 1)

    # gather back and gate-combine (dropped slots read the zero dump row)
    y_flat = y.transpose(1, 0, 2, 3).reshape(b, n_slots, d)
    y_flat = jnp.concatenate(
        [y_flat, jnp.zeros((b, 1, d), y_flat.dtype)], axis=1)
    tok = jnp.take_along_axis(y_flat, flat_idx[..., None], axis=1)  # (B,S·K,D)
    w = (gate_vals.reshape(b, s * k) * keep).astype(compute_dtype)
    out = jnp.sum(tok.reshape(b, s, k, d) * w.reshape(b, s, k, 1), axis=2)
    return out.astype(x.dtype)


def aux_load_balance_loss(logits: jax.Array, gate_idx: jax.Array,
                          n_experts: int) -> jax.Array:
    """Switch-style auxiliary loss: E · Σ_e f_e · P_e."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    p_mean = probs.mean(axis=(0, 1))
    f = jax.nn.one_hot(gate_idx[..., 0], n_experts).mean(axis=(0, 1))
    return n_experts * jnp.sum(f * p_mean)
