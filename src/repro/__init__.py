"""repro: CNNLab reproduced as a TPU-pod-scale JAX framework."""
__version__ = "1.0.0"
