"""End-to-end LM training driver (deliverable (b)): trains a ~100M-param
qwen2-family model for a few hundred steps on the synthetic pipeline.

    PYTHONPATH=src python examples/train_lm.py                 # ~100M, 300 steps
    PYTHONPATH=src python examples/train_lm.py --tiny          # CI-speed variant

This wraps launch/train.py's machinery directly (checkpointing, straggler
timer, WSD/cosine schedules) with an explicit ~100M config so the deliverable
is a single runnable script.
"""
import argparse
import dataclasses
import sys
import time

from repro.models.transformer import ModelConfig, count_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="reduced width/steps for CI smoke")
    ap.add_argument("--steps", type=int, default=0)
    args = ap.parse_args()

    if args.tiny:
        cfg = ModelConfig(name="lm-tiny", n_layers=2, d_model=64, n_heads=4,
                          n_kv_heads=2, d_ff=128, vocab=256)
        steps, batch, seq = 30, 4, 64
    else:
        # ~100M params: 12L x d=768 x ff=2048, 50k vocab
        cfg = ModelConfig(name="lm-100m", n_layers=12, d_model=768,
                          n_heads=12, n_kv_heads=4, d_ff=2048, vocab=50304,
                          scan_chunk=256, attention_impl="dot")
        steps, batch, seq = 300, 8, 256
    steps = args.steps or steps
    print(f"model: {cfg.name}, {count_params(cfg)/1e6:.1f}M params; "
          f"{steps} steps @ batch={batch} seq={seq}")

    # reuse the production trainer end to end (checkpointing, timers, WSD)
    from repro.launch import train as train_cli
    argv = ["--arch", "qwen2_1_5b", "--scale", "smoke", "--steps", str(steps),
            "--batch", str(batch), "--seq", str(seq),
            "--ckpt-dir", "/tmp/train_lm_ckpt", "--ckpt-interval", "100"]
    # swap in our config
    import repro.configs.registry as registry
    orig_get = registry.get

    def patched_get(name):
        spec = orig_get(name)
        return dataclasses.replace(spec, smoke=cfg)

    registry.get = patched_get
    old_argv = sys.argv
    sys.argv = ["train_lm"] + argv
    t0 = time.time()
    try:
        train_cli.main()
    finally:
        sys.argv = old_argv
        registry.get = orig_get
    print(f"total wall time {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
