"""CNNLab end-to-end: train the paper's AlexNet (Table I) on synthetic
images, then serve batched inference through scheduled engines.

    PYTHONPATH=src python examples/cnnlab_alexnet.py [--steps 30]

Uses a reduced input resolution by default so the CPU container finishes in
seconds; pass --full for the true 224x224 geometry (slower).
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engines, plan, scheduler
from repro.core.layer_model import (ConvSpec, FCSpec, NetworkSpec, NormSpec,
                                    PoolSpec, alexnet_full_spec)


def reduced_alexnet() -> NetworkSpec:
    """Same family, 32x32 input, for CPU-speed training demos."""
    L = (
        ConvSpec("Conv1", m_i=(32, 32, 3), m_k=(16, 3, 5, 5),
                 m_o=(16, 16, 16), stride=2, padding=2),
        NormSpec("LRN1", m_i=(16, 16, 16), norm_type="lrn", local_size=5),
        PoolSpec("Pool1", m_i=(16, 16, 16), m_o=(7, 7, 16), window=3,
                 stride=2),
        ConvSpec("Conv2", m_i=(7, 7, 16), m_k=(32, 16, 3, 3),
                 m_o=(7, 7, 32), stride=1, padding=1),
        PoolSpec("Pool2", m_i=(7, 7, 32), m_o=(3, 3, 32), window=3, stride=2),
        FCSpec("FC6", m_i=(32, 3, 3), k_o=128, activation="relu"),
        FCSpec("FC8", m_i=(128,), k_o=10, activation="softmax"),
    )
    return NetworkSpec("alexnet-reduced", L)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    net = alexnet_full_spec() if args.full else reduced_alexnet()
    res = net.layers[0].m_i[0]
    n_cls = net.layers[-1].k_o

    # CNNLab schedules the layers; compile into one differentiable program
    p = scheduler.schedule(net, engines.DEFAULT_ENGINES, objective="latency")
    print("schedule:", {a.spec.name: a.engine for a in p.assignments})
    apply_fn = plan.compile_plan(p)
    params = plan.init_network_params(net, jax.random.PRNGKey(0))

    # synthetic 'class = dominant color channel pattern' task
    rng = np.random.default_rng(0)

    def make_batch(n):
        y = rng.integers(0, n_cls, n)
        x = rng.normal(0, 0.3, (n, res, res, 3)).astype(np.float32)
        for i, cls in enumerate(y):
            x[i, :, :, cls % 3] += 0.5 + 0.2 * (cls % 4)
        return jnp.asarray(x), jnp.asarray(y)

    def loss_fn(ps, x, y):
        probs = apply_fn(x, ps)
        logp = jnp.log(jnp.maximum(probs, 1e-9))
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

    @jax.jit
    def step(ps, x, y):
        loss, g = jax.value_and_grad(loss_fn)(ps, x, y)
        ps = jax.tree.map(lambda p_, g_: p_ - 0.05 * g_, ps, g)
        return ps, loss

    for i in range(args.steps):
        x, y = make_batch(args.batch)
        params, loss = step(params, x, y)
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:3d} loss {float(loss):.4f}")

    # batched serving through the scheduled engines
    x, y = make_batch(64)
    t0 = time.perf_counter()
    probs = jax.jit(apply_fn)(x, params)
    probs.block_until_ready()
    dt = time.perf_counter() - t0
    acc = float(jnp.mean((jnp.argmax(probs, -1) == y)))
    print(f"\nserved batch of 64 in {dt*1e3:.1f} ms — accuracy {acc:.2f} "
          f"(chance {1/n_cls:.2f})")


if __name__ == "__main__":
    main()
