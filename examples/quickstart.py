"""Quickstart: the CNNLab middleware in five steps (paper §III).

    PYTHONPATH=src python examples/quickstart.py

1. declare a network as layer tuples;
2. let the scheduler run design-space exploration over the engine registry;
3. inspect the trade-off analysis (the paper's Fig. 6 quantities);
4. compile the plan into one JAX program;
5. run it.
"""
import jax
import jax.numpy as jnp

from repro.core import engines, plan, scheduler, tradeoff
from repro.core.device_models import DE5, K40
from repro.core.layer_model import alexnet_full_spec

# 1. the network: AlexNet declared as CNNLab layer tuples (paper Table I)
net = alexnet_full_spec()
print(f"network: {net.name}, {len(net)} layers, "
      f"{net.param_count()/1e6:.1f}M params, "
      f"{net.flops(1)/1e9:.2f} GFLOP/image\n")

# 2. design-space exploration across every registered engine
for objective in ("latency", "energy", "power"):
    p = scheduler.schedule(net, engines.ALL_ENGINES, objective=objective)
    picks = {a.engine for a in p.assignments}
    print(f"objective={objective:<9} -> engines {sorted(picks)} "
          f"time={p.total_time*1e3:.3f}ms energy={p.total_energy*1e3:.1f}mJ "
          f"peak={p.peak_power:.1f}W")

# 3. the paper's trade-off table (GPU vs FPGA, Fig. 6)
print("\nper-layer trade-off (batch=109, as calibrated to the paper):")
rows = tradeoff.analyze(net, [K40, DE5], batch=109)
print(f"{'layer':<8}{'device':<12}{'ms':>10}{'GFLOPS':>10}{'W':>8}{'J':>9}")
for r in rows:
    if r.layer in ("Conv1", "Conv4", "FC6", "FC8"):
        print(f"{r.layer:<8}{r.device:<12}{r.time_s*1e3:>10.3f}"
              f"{r.throughput_gflops:>10.1f}{r.power_w:>8.2f}"
              f"{r.energy_j:>9.3f}")

# 4. compile the TPU plan (xla + pallas engines) into one program
tpu_plan = scheduler.schedule(net, engines.DEFAULT_ENGINES,
                              objective="latency")
apply_fn = plan.compile_plan(tpu_plan)
params = plan.init_network_params(net, jax.random.PRNGKey(0))

# 5. run
x = jax.random.normal(jax.random.PRNGKey(1), (4, 224, 224, 3), jnp.float32)
probs = jax.jit(apply_fn)(x, params)
print(f"\ncompiled plan output: {probs.shape}, rows sum to "
      f"{[round(float(s), 4) for s in probs.sum(-1)]}")
print("engine per layer:",
      {a.spec.name: a.engine for a in tpu_plan.assignments})
