"""Batched LM serving example (deliverable (b)): prefill + decode loop with
request batching over the public API.

    PYTHONPATH=src python examples/serve_lm.py --requests 8 --batch 4
"""
import subprocess
import sys


def main():
    cmd = [sys.executable, "-m", "repro.launch.serve",
           "--arch", "qwen2_1_5b", "--scale", "smoke",
           "--batch", "4", "--prompt-len", "16", "--gen-len", "24",
           "--requests", "8"] + sys.argv[1:]
    raise SystemExit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
